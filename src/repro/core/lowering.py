"""The lowering pass: GNN spec -> per-layer ExecutionPlans (DESIGN.md §3).

This is the explicit form of Morphling's "code synthesis" step. Where the
paper's synthesizer emits backend-specialized source per layer, ``lower``
emits a ``ModelPlan`` — an inspectable list of ``LayerPlan`` records, each
naming the op kind, the dense/sparse feature path, the backend primitive
chosen from the registry (``repro.backends``), and carrying any pre-built
sparse operands (BSR of X and Xᵀ for the layer-0 sparse path; the weighted
graph's BSR/CSC pair shared by all layers).

The Algorithm-1 sparsity engine runs *per layer*, not just for layer 0:

* layer 0 — measured input-feature sparsity (``decide_execution_path``,
  exactly the single decision the seed repo made);
* hidden layers — post-activation sparsity estimates
  (``estimate_activation_sparsity``): ReLU zeroes ≈ half the entries, which
  stays below τ = 1 - γ for the paper's γ ≈ 0.2, so hidden layers land on
  the dense MXU path unless γ says otherwise.

A sparse *decision* only binds a sparse *primitive* when a pre-built operand
exists (layer 0, whose X is known at lowering time); hidden layers with a
sparse-profitable estimate record the decision and fall back to the dense
primitive, with the fallback noted in the plan — the plan never lies about
what will execute.

``GNNModel.apply`` executes plans directly; nothing monkey-patches model
methods anymore.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from repro.backends import Backend, select_backend
from repro.core.aggregate import FusedGraphOp, _weighted_graph, make_fused_aggregate
from repro.core.layout import (
    LayoutPlan,
    _select_order,
    default_layout,
    plan_layout,
)
from repro.core.sparsity import (
    PAPER_GAMMA_DEFAULT,
    SparsityDecision,
    decide_execution_path,
    decide_execution_path_from_stats,
    estimate_activation_sparsity,
)
from repro.core.verify import check_plan
from repro.graph.csr import CSRGraph, permute_graph


@dataclasses.dataclass(frozen=True)
class EpiloguePlan:
    """One layer's fused-epilogue record (DESIGN.md §8).

    Declares which epilogue operands the layer's aggregation fuses —
    ``alpha * self_term + bias`` then an optional activation — applied on
    the output tile while it is still resident (in VMEM on the Pallas
    backend, as an XLA-fused consumer elsewhere). ``apply_layer`` owns the
    per-arch algebra; this record is the plan's visible commitment plus the
    per-layer fallback gate (``None`` = unfused sequence of ops).
    """

    self_term: bool         # fuse alpha * self_term into the aggregation
    bias: bool              # fuse the bias add
    activation: str         # "relu" (mask saved for the VJP) | "none"
    formula: str            # human-readable algebra, for plan dumps

    def describe(self) -> str:
        return self.formula


@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    """One layer's fused-attention record (DESIGN.md §10).

    The attention sibling of ``EpiloguePlan``: declares how a GAT /
    GraphTransformer layer's edge-softmax aggregation executes. ``fused``
    means the flash-style BSR kernel (online segment softmax + aggregation
    in one pass, per-edge scores never materialised) with the recompute VJP
    from the saved per-row (max, denominator) stats; unfused is the segment
    (gather) path with autodiff through the per-edge tensors.
    """

    heads: int
    head_dim: int
    fused: bool
    vjp: str                # "recompute(m,l)" | "autodiff"
    formula: str            # human-readable algebra, for plan dumps

    def describe(self) -> str:
        mode = "fused-bsr" if self.fused else "segment"
        return (f"{self.heads}h x {self.head_dim} {mode} vjp={self.vjp} "
                f"{self.formula}")


def _attention_binding(heads: int, d_out: int, fused: bool) -> AttentionPlan:
    head_dim = max(d_out // heads, 1)
    return AttentionPlan(
        heads=heads, head_dim=head_dim, fused=fused,
        vjp="recompute(m,l)" if fused else "autodiff",
        formula="softmax_j(leaky_relu(a_dst·z_i + a_src·z_j))·z_j")


def is_attention_arch(kind: str) -> bool:
    """Archs whose aggregation is the edge-softmax attention primitive."""
    return kind in ("GAT", "GT")


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    """The distributed plan's split-phase execution record (DESIGN.md §11).

    Declares that every matmul/attention aggregation layer runs the
    interior SpMM (local columns only) concurrently with the halo
    exchange's ``ppermute`` rounds, then the boundary SpMM once ghosts
    land — forward and backward both (the interior transposed-SpMM is off
    the reverse-exchange path by construction). ``live_shifts`` is the
    host-computed set of ring shifts with at least one live send on any
    rank; dead shifts are not unrolled. ``double_buffer_slots`` is the
    ghost-buffer rotation depth the trainer's ``GhostBufferRing`` schedules
    (adjacent layers never share a slot). ``prefetch_depth`` > 0 marks
    host-streamed operands (``runtime/streaming.py``): strips staged that
    many steps ahead of the consuming SpMM.
    """

    interior_blocks: int        # fleet-total interior stream length
    boundary_blocks: int        # fleet-total boundary stream length
    live_shifts: tuple          # ring shifts actually unrolled
    total_shifts: int           # P - 1
    double_buffer_slots: int = 2
    prefetch_depth: int = 0     # 0 = device-resident operands

    def describe(self) -> str:
        line = (f"split-phase int={self.interior_blocks}b "
                f"bnd={self.boundary_blocks}b "
                f"shifts={len(self.live_shifts)}/{self.total_shifts} "
                f"ghost-slots={self.double_buffer_slots}")
        if self.prefetch_depth:
            line += f" prefetch={self.prefetch_depth}"
        return line


@dataclasses.dataclass
class LayerPlan:
    """One layer's synthesized execution record."""

    index: int
    op_kind: str            # GCN | SAGE | GIN | GAT | GT
    d_in: int
    d_out: int
    feature_path: str       # "sparse" | "dense" — the path that will execute
    primitive: str          # backend primitive for the feature transform
    agg_primitive: str      # backend primitive for neighbour aggregation
    decision: SparsityDecision  # this layer's Alg-1 decision
    # differentiable w -> X @ w over pre-built BSR(X)/BSR(Xᵀ); only set when
    # feature_path == "sparse" (layer 0 with a known feature matrix)
    sparse_xw: Optional[Callable] = dataclasses.field(
        default=None, repr=False, compare=False)
    note: str = ""
    # fused-epilogue binding; None = unfused aggregation + separate XLA ops
    epilogue: Optional[EpiloguePlan] = None
    # attention binding (GAT / GT layers); None for non-attention archs
    attention: Optional[AttentionPlan] = None
    # the layout the layer's sparse operands were built at (shared across a
    # plan's layers); None = pre-layout-stage plans
    layout: Optional[LayoutPlan] = None

    def describe(self) -> str:
        d = self.decision
        line = (
            f"layer {self.index}: {self.op_kind:4s} [{self.d_in} -> {self.d_out}]  "
            f"path={self.feature_path:6s} primitive={self.primitive}  "
            f"agg={self.agg_primitive}  "
            f"s={d.sparsity:.3f} tau={d.threshold:.2f} mode={d.mode}"
        )
        if self.epilogue is not None:
            line += f"  epilogue[{self.epilogue.describe()}]"
        if self.attention is not None:
            line += f"  attention[{self.attention.describe()}]"
        if self.layout is not None:
            line += f"  layout[{self.layout.describe()}]"
        if self.note:
            line += f"  ({self.note})"
        return line


@dataclasses.dataclass
class ModelPlan:
    """The synthesized program, made visible: per-layer plans + shared ops."""

    layers: list[LayerPlan]
    backend: str            # registry name of the chosen backend
    gamma: float
    arch: str
    aggregation: str        # effective aggregation ("gcn", "sum", ...)
    feature_sparsity: float  # measured input sparsity (0.0 if unknown)
    graph_op: FusedGraphOp = dataclasses.field(repr=False)
    # the layout stage's decision: node order + BSR tile the operands were
    # materialised at; carries perm/inv_perm when the order permutes
    layout: Optional[LayoutPlan] = None

    @property
    def input_decision(self) -> SparsityDecision:
        """Layer 0's decision — the seed repo's single ``sparsity_decision``."""
        return self.layers[0].decision

    def describe(self) -> str:
        head = (
            f"ModelPlan: arch={self.arch} backend={self.backend} "
            f"aggregation={self.aggregation} gamma={self.gamma:.2f} "
            f"input_sparsity={self.feature_sparsity:.3f} "
            f"layers={len(self.layers)}"
        )
        return "\n".join([head] + ["  " + l.describe() for l in self.layers])


@dataclasses.dataclass
class DistributedModelPlan:
    """The synthesized *distributed* program: per-layer plans whose
    aggregation primitives are the halo-exchange compositions from
    ``backends/distributed.py``, plus the stacked per-rank sparse operands
    for the layer-0 Alg-1 input path (DESIGN.md §6)."""

    layers: list[LayerPlan]
    backend: str            # "distributed"
    inner: str              # local SpMM executor: "pallas" | "xla"
    gamma: float
    arch: str
    aggregation: str
    n_ranks: int
    feature_sparsity: float             # pooled over valid rows, all ranks
    per_rank_sparsity: np.ndarray       # [P] measured per-rank input sparsity
    # stacked per-rank BSR(X_local) / BSR(X_localᵀ) — bound iff layer 0 took
    # the sparse path; passed into shard_map as sharded arguments
    feat_fwd: Optional[dict] = dataclasses.field(default=None, repr=False)
    feat_bwd: Optional[dict] = dataclasses.field(default=None, repr=False)
    feat_f_pad: int = 0                 # shared padded feature dim of the pair
    # within-rank order + the tile the stacked operands were built at; the
    # permutation is baked into the data distribution (perm=None here)
    layout: Optional[LayoutPlan] = None
    # split-phase overlap record; None = bulk-synchronous fallback (the
    # overlap=False flag, or a DistributedGraph built without split operands,
    # or an aggregation with no overlapped composition)
    overlap: Optional[OverlapPlan] = None

    @property
    def input_decision(self) -> SparsityDecision:
        return self.layers[0].decision

    def describe(self) -> str:
        s = self.per_rank_sparsity
        head = (
            f"DistributedModelPlan: arch={self.arch} backend={self.backend} "
            f"inner={self.inner} ranks={self.n_ranks} "
            f"aggregation={self.aggregation} gamma={self.gamma:.2f} "
            f"input_sparsity={self.feature_sparsity:.3f} "
            f"per_rank_s=[{s.min():.3f}, {s.max():.3f}] layers={len(self.layers)}"
        )
        if self.overlap is not None:
            head += f"\n  overlap[{self.overlap.describe()}]"
        return "\n".join([head] + ["  " + l.describe() for l in self.layers])


@dataclasses.dataclass
class SampledModelPlan:
    """The synthesized *mini-batch* program (DESIGN.md §7): per-layer plans
    whose aggregation primitives run on the sampler's bucketed
    ``SampledBlock`` operands, plus the template-batch Alg-1 decision for
    the per-batch sparse input path. The third consumer of the plan
    pipeline, and the first whose graph size is independent of device
    memory."""

    layers: list[LayerPlan]
    backend: str
    gamma: float
    arch: str
    aggregation: str
    feature_sparsity: float   # measured on the template batch's frontier
    fanouts: tuple[int, ...]
    batch_size: int
    n_buckets: int
    sampler: object = dataclasses.field(repr=False)  # graph.sampling.NeighborSampler
    # full-graph order the sampler's CSR was renumbered with (the trainer
    # maps user node ids through inv_perm) + the sampler's block tile
    layout: Optional[LayoutPlan] = None
    # serving plans: the trainer never builds loss/grad closures — the
    # compiled artifact is the infer path only (DESIGN.md §12)
    infer_only: bool = False

    @property
    def input_decision(self) -> SparsityDecision:
        return self.layers[0].decision

    def describe(self) -> str:
        head = (
            f"SampledModelPlan: arch={self.arch} backend={self.backend} "
            f"aggregation={self.aggregation} gamma={self.gamma:.2f} "
            f"fanouts={list(self.fanouts)} batch={self.batch_size} "
            f"buckets={self.n_buckets} "
            f"frontier_sparsity={self.feature_sparsity:.3f} "
            f"layers={len(self.layers)}"
            + (" infer_only" if self.infer_only else "")
        )
        lines = [head] + ["  " + l.describe() for l in self.layers]
        for b in self.sampler.buckets:
            lines.append(
                f"  bucket[seed_cap={b.seed_cap}]: node_caps={list(b.node_caps)} "
                f"nnz_caps={list(b.nnz_caps)} feat_nnz_cap={b.feat_nnz_cap}")
        return "\n".join(lines)


def lower_sampled(
    config,
    graph: CSRGraph,
    features: np.ndarray,
    *,
    fanouts,
    batch_size: int = 256,
    n_buckets: int = 2,
    gamma: float = PAPER_GAMMA_DEFAULT,
    engine: "str | Backend | None" = None,
    br: int = 8,
    bc: int = 8,
    seed: int = 0,
    use_sparse_input: bool = True,
    feat_slack: float = 2.0,
    fuse_epilogue: bool = True,
    fuse_attention: bool = True,
    layout: "LayoutPlan | str | None" = None,
    infer_only: bool = False,
    validate: str = "fast",
) -> SampledModelPlan:
    """Lower a GNN spec onto the neighbour-sampled mini-batch path.

    The graph is pre-weighted for the spec's aggregation (full-graph
    normalisation, the parity anchor with the full-batch path) and handed
    to a ``NeighborSampler`` whose bucketed shape caps bound jit retraces
    to one per bucket. The Algorithm-1 engine runs on the *gathered
    frontier features of a template batch*: a sampled batch is simply a
    smaller operand with a fresh sparsity decision. A sparse layer-0
    decision binds the gather-layout ``feature_matmul_sparse`` primitive —
    the batch's feature matrix is a runtime value, so the sampler streams
    per-batch COO operands (capped at ``feat_slack`` times the template's
    measured density; denser batches fall back to the dense MXU path and
    are counted by the trainer).

    ``layout`` requests the reorder stage (DESIGN.md §9): the full graph is
    renumbered before the sampler is built, so every sampled block's source
    frontier clusters renumbered neighbours and the per-batch CSR→BSR packs
    denser blocks. The plan's ``layout.perm``/``inv_perm`` is the id map
    ``MiniBatchTrainer`` applies at its boundary (user node ids in,
    seed-ordered logits out — the permutation never reaches the caller).
    The block tile stays the sampler's ``(br, bc)``: bucketed rectangular
    operands do not share the full-graph tile geometry.

    ``infer_only=True`` marks the plan as a serving artifact (DESIGN.md
    §12): the trainer executing it never builds loss/grad closures.
    """
    from repro.graph.sampling import NeighborSampler

    backend = select_backend(engine)
    if backend.name == "distributed":
        raise ValueError("use lower_distributed for the distributed backend")
    kind = config.kind
    dims = list(config.layer_dims)
    features = np.asarray(features)
    if features.shape[-1] != dims[0]:
        raise ValueError(
            f"layer_dims[0]={dims[0]} != feature dim {features.shape[-1]}")
    if isinstance(fanouts, int):
        fanouts = (fanouts,) * config.n_layers
    fanouts = tuple(int(f) for f in fanouts)
    if len(fanouts) != config.n_layers:
        raise ValueError(
            f"need one fanout per layer ({config.n_layers}), got {fanouts!r}")

    if isinstance(layout, LayoutPlan):
        lp = dataclasses.replace(
            layout, br=int(br), bc=int(bc), bf=0, n_blocks=0,
            padding_waste=0.0, source="sampled")
    else:
        if layout is None:
            mode, g_r, perm, inv = "none", graph, None, None
        else:
            mode, g_r, perm, inv = _select_order(graph, layout)
        lp = LayoutPlan(order=mode, br=int(br), bc=int(bc), perm=perm,
                        inv_perm=inv, source="sampled",
                        reordered_graph=g_r if mode != "none" else None)
    if lp.permutes:
        graph = (lp.reordered_graph if lp.reordered_graph is not None
                 else permute_graph(graph, lp.inv_perm))
        features = features[lp.perm]
    if lp.reordered_graph is not None:  # sampler holds its own weighted copy
        lp = dataclasses.replace(lp, reordered_graph=None)

    agg = effective_aggregation(config)
    weighted = _weighted_graph(graph, agg)
    is_attn = is_attention_arch(kind)
    # matmul-expressible aggregations ride the BSR operands; attention archs
    # join them when the fused attention kernel is on (the per-batch BSR
    # nonzero pattern doubles as the attention mask); max stays edge-valued
    emit_attn = (fuse_attention and is_attn
                 and backend.name in ("pallas", "xla"))
    emit_bsr = (backend.name in ("pallas", "xla")
                and (emit_attn if is_attn else agg != "max"))
    sampler = NeighborSampler(
        weighted, fanouts, batch_size, n_buckets=n_buckets, br=br, bc=bc,
        seed=seed, emit_bsr=emit_bsr)

    # template batch: Alg-1 input statistics on a gathered frontier
    t_rng = np.random.default_rng(seed ^ 0x5EED)
    t_seeds = t_rng.choice(
        graph.n_rows, size=min(batch_size, graph.n_rows), replace=False)
    template = sampler.sample_batch(t_seeds, rng=t_rng)
    frontier0 = template.blocks[0].src_nodes
    rows = features[frontier0]
    s_frontier = 1.0 - np.count_nonzero(rows) / max(rows.size, 1)

    emit_epilogue = fuse_epilogue and epilogue_fusable(config, agg)
    if is_attn:
        agg_primitive = (f"{backend.name}.spmm_attention" if emit_attn
                         else f"{backend.name}.segment_softmax_aggregate")
    elif agg == "max":
        agg_primitive = "gather.segment_max"
    elif emit_epilogue:
        # same labeling as lower(): the executed contract is the fused
        # epilogue over whatever aggregation the backend serves
        agg_primitive = f"{backend.name}.spmm_fused_epilogue"
    elif backend.name == "gather":
        agg_primitive = "gather.segment_sum_baseline"
    else:
        agg_primitive = f"{backend.name}.spmm_transposed_vjp"

    layers: list[LayerPlan] = []
    for i in range(config.n_layers):
        d_in, d_out = dims[i], dims[i + 1]
        if i == 0:
            decision = decide_execution_path_from_stats(
                s_frontier, int(frontier0.shape[0]), d_in, d_out, gamma=gamma)
        else:
            s_est = estimate_activation_sparsity(config.activation)
            decision = decide_execution_path_from_stats(
                s_est, int(frontier0.shape[0]), d_in, d_out, gamma=gamma)

        path, primitive, note = "dense", f"{backend.name}.feature_matmul_dense", ""
        if i == 0 and decision.mode == "sparse":
            expressible, expr_note = _sparse_expressible(kind)
            if not use_sparse_input:
                note = "sparse profitable but disabled (use_sparse_input=False)"
            elif not expressible:
                note = expr_note
            else:
                # per-batch feature matrices are runtime values: the sampler
                # streams COO operands in the gather backend's edge-list
                # layout, capped by the template's measured density
                f_dim = dims[0]
                caps = [
                    max(min(int(np.ceil(b.node_caps[0] * f_dim
                                        * (1.0 - s_frontier) * feat_slack)),
                            b.node_caps[0] * f_dim), 1)
                    for b in sampler.buckets
                ]
                sampler.set_feature_caps(caps)
                path = "sparse"
                primitive = "gather.feature_matmul_sparse"
                note = (f"per-batch COO operand streamed by the sampler "
                        f"(slack={feat_slack:g})")
                if expr_note:
                    note += f"; {expr_note}"
        elif decision.mode == "sparse":
            note = ("sparse profitable but activations are runtime values; "
                    "no pre-built operand — dense fallback")

        epilogue = None
        if emit_epilogue:
            epilogue = _epilogue_binding(
                config, is_last=(i == config.n_layers - 1),
                sparse_path=(path == "sparse"))
        attention = None
        if is_attn:
            attention = _attention_binding(config.gat_heads, d_out, emit_attn)

        layers.append(LayerPlan(
            index=i, op_kind=kind, d_in=d_in, d_out=d_out,
            feature_path=path, primitive=primitive,
            agg_primitive=agg_primitive, decision=decision, note=note,
            epilogue=epilogue, attention=attention, layout=lp,
        ))

    plan = SampledModelPlan(
        layers=layers, backend=backend.name, gamma=gamma, arch=kind,
        aggregation=agg, feature_sparsity=float(s_frontier), fanouts=fanouts,
        batch_size=int(batch_size), n_buckets=int(n_buckets), sampler=sampler,
        layout=lp, infer_only=bool(infer_only),
    )
    check_plan(plan, mode=validate)
    return plan


def effective_aggregation(config) -> str:
    """The aggregation the spec actually lowers to (the seed model's
    normalisation): GCN always uses symmetric-normalised weights, GIN's sum
    is fixed by the arch, everything else takes ``config.aggregation``.
    Shared by ``lower``/``lower_distributed`` and every call site that
    pre-weights a ``DistributedGraph``."""
    if config.kind == "GCN":
        return "gcn"
    if config.kind == "GIN":
        return "sum"
    return config.aggregation


def lower_distributed(
    config,
    dist,  # core.halo.DistributedGraph
    features: Optional[np.ndarray] = None,  # [P, n_local, F]; default dist's
    *,
    gamma: float = PAPER_GAMMA_DEFAULT,
    inner: Optional[str] = None,
    use_sparse_input: bool = True,
    fuse_epilogue: bool = True,
    fuse_attention: bool = True,
    overlap: bool = True,
    validate: str = "fast",
) -> DistributedModelPlan:
    """Lower a GNN spec onto the distributed backend: the MPI-analog
    synthesis step.

    The Alg-1 layer-0 decision runs on *per-rank* feature statistics
    (padding rows excluded via ``dist.n_valid``). The bound path must be
    SPMD-uniform — one jitted program across ranks — so the sparse input
    path binds iff **every** rank's decision is sparse; a mixed fleet falls
    back to dense with the per-rank spread recorded in the plan note. When
    the sparse path binds, the per-rank BSR(X_local)/BSR(X_localᵀ) pairs
    are built here, stacked on the rank axis like the graph operands.

    ``overlap=True`` (the default) binds the split-phase compositions —
    interior SpMM concurrent with the halo exchange, boundary SpMM after —
    recorded as an ``OverlapPlan`` on the returned plan. It falls back to
    the bulk-synchronous primitives (``overlap=None`` on the plan) when
    the ``DistributedGraph`` carries no split operands, or when the
    aggregation has no overlapped form (``max`` and the unfused segment
    attention path consume the ghost buffer directly)."""
    from repro.backends import get_backend
    from repro.core.halo import stack_bsr_matrices
    from repro.graph.csr import csr_from_dense, csr_to_bsr

    backend = get_backend("distributed")
    inner_name = inner or backend.inner()
    kind = config.kind
    dims = list(config.layer_dims)
    P = dist.n_ranks

    agg = effective_aggregation(config)
    if dist.aggregation not in ("sum", agg):
        raise ValueError(
            f"DistributedGraph was weighted for {dist.aggregation!r} but the "
            f"spec needs {agg!r}; rebuild with build_distributed_graph(..., "
            f"aggregation={agg!r})")

    emit_epilogue = fuse_epilogue and epilogue_fusable(config, agg)
    is_attn = is_attention_arch(kind)
    # the distributed inner executor is always pallas/xla, so the fused
    # attention composition is available whenever the flag is on
    emit_attn = fuse_attention and is_attn
    # split-phase overlap: needs the split operands on the DistributedGraph
    # and an aggregation with an overlapped composition (matmul or fused
    # attention; max / segment attention consume the ghost buffer directly)
    split_built = getattr(dist, "fwd_interior", None) is not None
    emit_overlap = (overlap and split_built and agg != "max"
                    and (emit_attn if is_attn else True))
    if is_attn:
        if emit_attn:
            agg_primitive = ("distributed.dist_spmm_attention_split"
                             if emit_overlap
                             else "distributed.dist_spmm_attention")
        else:
            agg_primitive = "distributed.dist_segment_softmax_aggregate"
    elif agg == "max":
        agg_primitive = "distributed.dist_segment_max"
    elif emit_epilogue:
        agg_primitive = ("distributed.dist_spmm_fused_epilogue_split"
                         if emit_overlap
                         else "distributed.dist_spmm_fused_epilogue")
    else:
        agg_primitive = ("distributed.dist_spmm_split_transposed_vjp"
                         if emit_overlap
                         else "distributed.dist_spmm_transposed_vjp")

    overlap_plan = None
    if emit_overlap:
        overlap_plan = OverlapPlan(
            interior_blocks=int(np.asarray(dist.interior_blocks).sum()),
            boundary_blocks=int(np.asarray(dist.boundary_blocks).sum()),
            live_shifts=tuple(dist.live_shifts or ()),
            total_shifts=P - 1,
        )

    feats = np.asarray(dist.features if features is None else features)
    if feats.shape[0] != P or feats.shape[1] != dist.n_local:
        raise ValueError(
            f"features must be rank-stacked [P={P}, n_local={dist.n_local}, F]")
    f_dim = feats.shape[-1]
    if dims[0] != f_dim:
        raise ValueError(f"layer_dims[0]={dims[0]} != feature dim {f_dim}")

    # within-rank order + tile the stacked operands were built at
    # (build_distributed_graph applied the reorder per rank; the
    # permutation is baked into the data distribution, so no
    # trainer-boundary perm — loss and grads are order-invariant)
    lp = LayoutPlan(order=getattr(dist, "reorder", "none"),
                    br=dist.br, bc=dist.bc, bf=0, source="distributed")

    n_valid = (np.asarray(dist.n_valid) if dist.n_valid is not None
               else np.full(P, dist.n_local))
    per_rank_s = np.zeros(P)
    nnz_total = 0
    for p in range(P):
        rows = feats[p, : n_valid[p]]
        nnz = np.count_nonzero(rows)
        per_rank_s[p] = 1.0 - nnz / max(rows.size, 1)
        nnz_total += nnz
    pooled_s = 1.0 - nnz_total / max(int(n_valid.sum()) * f_dim, 1)

    # per-rank Alg-1 decisions for layer 0; pooled record kept on the plan
    rank_decisions = [
        decide_execution_path_from_stats(
            per_rank_s[p], int(n_valid[p]), dims[0], dims[1], gamma=gamma)
        for p in range(P)
    ]
    all_sparse = all(d.mode == "sparse" for d in rank_decisions)

    feat_fwd = feat_bwd = None
    f_pad = 0
    layers: list[LayerPlan] = []
    for i in range(config.n_layers):
        d_in, d_out = dims[i], dims[i + 1]
        if i == 0:
            decision = decide_execution_path_from_stats(
                pooled_s, int(n_valid.sum()), d_in, d_out, gamma=gamma)
        else:
            s_est = estimate_activation_sparsity(config.activation)
            decision = decide_execution_path_from_stats(
                s_est, int(n_valid.sum()), d_in, d_out, gamma=gamma)

        path, primitive, note = "dense", "distributed.feature_matmul_dense", ""
        if i == 0 and decision.mode == "sparse":
            expressible, expr_note = _sparse_expressible(kind)
            if not use_sparse_input:
                note = "sparse profitable but disabled (use_sparse_input=False)"
            elif not expressible:
                note = expr_note
            elif not all_sparse:
                note = (f"mixed fleet: {sum(d.mode == 'sparse' for d in rank_decisions)}"
                        f"/{P} ranks sparse — SPMD-uniform dense fallback")
            else:
                # build the stacked per-rank sparse operands once, here
                br, bc = dist.br, dist.bc
                mult = int(np.lcm(br, bc))
                f_pad = -(-f_dim // mult) * mult
                fwd_stack, bwd_stack = [], []
                for p in range(P):
                    x_csr = csr_from_dense(feats[p])
                    x_csr = dataclasses.replace(x_csr, n_cols=f_pad)
                    fwd_stack.append(csr_to_bsr(x_csr, br=br, bc=bc))
                    bwd_stack.append(csr_to_bsr(x_csr.transpose(), br=br, bc=bc))
                feat_fwd = stack_bsr_matrices(fwd_stack, br, bc)
                feat_bwd = stack_bsr_matrices(bwd_stack, br, bc)
                path = "sparse"
                primitive = "distributed.dist_feature_matmul_sparse"
                note = (f"per-rank BSR(X_local); s in "
                        f"[{per_rank_s.min():.3f}, {per_rank_s.max():.3f}]")
                if expr_note:
                    note += f"; {expr_note}"
        elif decision.mode == "sparse":
            note = ("sparse profitable but activations are runtime values; "
                    "no pre-built operand — dense fallback")

        epilogue = None
        if emit_epilogue:
            epilogue = _epilogue_binding(
                config, is_last=(i == config.n_layers - 1),
                sparse_path=(path == "sparse"))
        attention = None
        if is_attn:
            attention = _attention_binding(config.gat_heads, d_out, emit_attn)

        layers.append(LayerPlan(
            index=i, op_kind=kind, d_in=d_in, d_out=d_out,
            feature_path=path, primitive=primitive,
            agg_primitive=agg_primitive, decision=decision, note=note,
            epilogue=epilogue, attention=attention, layout=lp,
        ))

    plan = DistributedModelPlan(
        layers=layers, backend="distributed", inner=inner_name, gamma=gamma,
        arch=kind, aggregation=agg, n_ranks=P, feature_sparsity=pooled_s,
        per_rank_sparsity=per_rank_s, feat_fwd=feat_fwd, feat_bwd=feat_bwd,
        feat_f_pad=f_pad, layout=lp, overlap=overlap_plan,
    )
    check_plan(plan, mode=validate, dist=dist)
    return plan


def epilogue_fusable(config, aggregation: str) -> bool:
    """Can this spec's aggregate layers take a fused epilogue at all?

    The epilogue rides the matmul-form aggregation: attention archs
    (GAT/GT) aggregate through the attention primitive instead (their
    fusion story is ``AttentionPlan``, DESIGN.md §10) and ``max`` is not a
    matmul — both keep the unfused epilogue sequence.
    """
    return not is_attention_arch(config.kind) and aggregation != "max"


def _epilogue_binding(config, is_last: bool,
                      sparse_path: bool) -> Optional[EpiloguePlan]:
    """The per-layer epilogue record (DESIGN.md §8 grammar).

    Only a ReLU activation lowers into the kernel (the mask-VJP contract);
    any other ``config.activation`` fuses self-term/bias and leaves the
    activation outside. Per arch:

    * GCN  — ``relu(A·(X·W) + b)``: bias + post-activation.
    * SAGE — ``relu(A·(X·Wn) + X·Ws + b)``: the self/neigh combine. The
      neighbour transform reassociates ``A(X)·Wn == A(X·Wn)`` (A is linear),
      so the self term, bias and activation all land on the SpMM output.
    * GIN  — sparse-reassociated layers fuse the whole MLP input
      ``act(A·u + (1+eps)·u + b1), u = X·W1``; dense layers fuse the
      self-term combine ``A·x + (1+eps)·x`` (bias/activation belong to the
      dense MLP matmul that follows, which XLA fuses on its own).
    """
    kind = config.kind
    relu_ok = config.activation is jax.nn.relu
    post = "relu" if (relu_ok and not is_last) else "none"
    if kind == "GCN":
        f = "A·(X·W) + b"
        return EpiloguePlan(self_term=False, bias=True, activation=post,
                            formula=f"relu({f})" if post == "relu" else f)
    if kind == "SAGE":
        f = "A·(X·Wn) + X·Ws + b"
        return EpiloguePlan(self_term=True, bias=True, activation=post,
                            formula=f"relu({f})" if post == "relu" else f)
    if kind == "GIN":
        if sparse_path:
            act = "relu" if relu_ok else "none"
            f = "A·u + (1+eps)·u + b1, u = X·W1"
            return EpiloguePlan(self_term=True, bias=True, activation=act,
                                formula=f"relu({f})" if act == "relu" else f)
        return EpiloguePlan(self_term=True, bias=False, activation="none",
                            formula="A·x + (1+eps)·x")
    return None


def _sparse_expressible(kind: str) -> tuple[bool, str]:
    """Can the layer-0 X @ W be served by ``feature_matmul_sparse``?

    GCN/SAGE/GAT/GT multiply raw X by a weight directly. GIN's MLP input is
    (1+eps)·X + A·X, but its aggregation is the linear "sum" operator, so
    z @ W1 re-associates to (1+eps)·(X@W1) + A·(X@W1) — the sparse matmul
    applies there too (and shrinks the aggregation from F to H columns).
    """
    if kind in ("GCN", "SAGE", "GAT", "GT"):
        return True, ""
    if kind == "GIN":
        return True, "reassociated: z@W1 = (1+eps)(X@W1) + A(X@W1)"
    return False, f"no sparse lowering for {kind}"


def _resolve_layout(
    graph: CSRGraph,
    f_dim: int,
    backend_name: str,
    fused: bool,
    layout: "LayoutPlan | str | None",
    br: Optional[int],
    bc: Optional[int],
    interpret: Optional[bool],
    n_heads: int = 0,
    attention: bool = False,
) -> LayoutPlan:
    """Turn a ``layout=`` argument into a concrete ``LayoutPlan``.

    * ``None`` — the un-autotuned fallback: identity order, explicit
      ``br``/``bc`` when given, adaptive ``bc`` otherwise (satellite fix:
      small graphs stop lane-padding to 128).
    * ``"auto"`` — the full layout stage: order selection + tile
      autotuning with the disk cache (``core/layout.py:plan_layout``).
    * ``"none" | "degree" | "rcm"`` — that order with the fallback tile
      (or an explicit ``br``/``bc``; no measurement — deterministic, what
      the parity tests pin).
    * a ``LayoutPlan`` — passes through untouched.

    Explicit ``br``/``bc`` combined with ``"auto"`` or a ``LayoutPlan``
    is a conflict (the layout carries the tile) and raises rather than
    silently discarding the caller's tile.
    """
    if isinstance(layout, LayoutPlan) or layout == "auto":
        if br is not None or bc is not None:
            raise ValueError(
                f"explicit br/bc conflict with layout={layout!r}: the "
                f"layout carries the tile — pass one or the other")
        if isinstance(layout, LayoutPlan):
            return layout
        return plan_layout(graph, f_dim, backend=backend_name, fused=fused,
                           interpret=interpret, n_heads=n_heads,
                           attention=attention)
    if layout is None or layout == "none":
        lp = default_layout(graph, br=br, bc=bc)
        if br is not None or bc is not None:
            lp.source = "explicit"
        return lp
    mode, g_r, perm, inv = _select_order(graph, layout)  # validates mode
    if mode == "none":
        return default_layout(graph, br=br, bc=bc)
    lp = default_layout(g_r, br=br, bc=bc)
    return dataclasses.replace(lp, order=mode, perm=perm, inv_perm=inv,
                               source="requested", reordered_graph=g_r)


def lower(
    config,
    graph: CSRGraph,
    features: Optional[np.ndarray] = None,
    *,
    gamma: float = PAPER_GAMMA_DEFAULT,
    engine: "str | Backend | None" = None,
    interpret: Optional[bool] = None,
    use_fused: bool = True,
    fuse_epilogue: bool = True,
    fuse_attention: bool = True,
    br: Optional[int] = None,
    bc: Optional[int] = None,
    layout: "LayoutPlan | str | None" = None,
    validate: str = "fast",
) -> ModelPlan:
    """Lower a GNN spec onto backend primitives: the synthesis step.

    ``config`` is a ``models.gnn.GNNConfig`` (duck-typed: ``kind``,
    ``layer_dims``, ``aggregation``, ``activation``, ``n_layers``).
    ``features=None`` means the input matrix is unknown at lowering time
    (direct ``GNNModel`` construction); every layer then takes the dense
    path. ``use_fused=False`` keeps the plan but executes aggregation on the
    gather-scatter baseline and disables sparse feature binding, preserving
    the seed repo's A/B-comparison semantics. ``fuse_epilogue=False`` keeps
    the fused aggregation but unbinds the per-layer epilogue (bias /
    self-term / activation run as separate XLA ops) — the A/B lever
    ``benchmarks/bench_fusion.py`` sweeps. ``fuse_attention=False`` keeps
    attention archs (GAT / GT) on the segment-softmax gather path — the
    A/B lever ``benchmarks/bench_attention.py`` sweeps; by default they
    lower onto the fused BSR flash-attention kernel on pallas/xla.

    ``layout`` selects the layout-optimization stage (DESIGN.md §9):
    ``"auto"`` reorders the graph (degree / RCM, whichever packs BSR blocks
    densest) and autotunes the ``(br, bc, bf)`` tile with the disk-cached
    microbenchmark; every sparse operand is then built once from the
    reordered graph, and the plan carries ``perm``/``inv_perm`` so
    ``GNNModel.apply`` permutes features in and un-permutes outputs —
    results are bit-for-bit up to the permutation. Explicit ``br``/``bc``
    keep their legacy meaning (``bc=None`` now defaults adaptively instead
    of lane-padding small graphs to 128) but conflict with ``"auto"`` / a
    ``LayoutPlan`` — the layout carries the tile, so that combination
    raises instead of silently dropping the caller's tile.
    """
    backend = select_backend(engine)
    kind = config.kind
    dims = list(config.layer_dims)

    agg = effective_aggregation(config)

    emit_fused_epi = (use_fused and fuse_epilogue
                      and epilogue_fusable(config, agg))
    is_attn = is_attention_arch(kind)
    emit_attn = (use_fused and fuse_attention and is_attn
                 and backend.name in ("pallas", "xla"))
    # the autotuner measures at the width the aggregation SpMM actually
    # runs: every arch aggregates post-transform tensors of the hidden
    # width (GCN A·(XW), SAGE A·(XWn), GIN-reassociated A·u)
    agg_width = dims[1] if len(dims) > 1 else dims[0]
    lp = _resolve_layout(graph, agg_width, backend.name, emit_fused_epi,
                         layout, br, bc, interpret,
                         n_heads=config.gat_heads if is_attn else 0,
                         attention=emit_attn)
    if lp.permutes:
        graph_exec = (lp.reordered_graph if lp.reordered_graph is not None
                      else permute_graph(graph, lp.inv_perm))
        features_exec = (None if features is None
                         else np.asarray(features)[lp.perm])
    else:
        graph_exec = graph
        features_exec = None if features is None else np.asarray(features)
    n_nodes = graph_exec.n_rows

    graph_op = make_fused_aggregate(
        graph_exec, agg, br=lp.br, bc=lp.bc, interpret=interpret,
        engine=backend, bf=lp.bf or None, build_attention=emit_attn)
    # operands are built — drop the layout's host-side graph copy so the
    # plan (held for the model's lifetime) doesn't duplicate the graph
    if lp.reordered_graph is not None:
        lp = dataclasses.replace(lp, reordered_graph=None)

    emit_epilogue = emit_fused_epi
    attn_bound = emit_attn and graph_op.aggregate_attention is not None
    if is_attn:
        agg_primitive = (f"{backend.name}.spmm_attention" if attn_bound
                         else f"{backend.name}.segment_softmax_aggregate")
    elif agg == "max":
        agg_primitive = "gather.segment_max"  # not a matmul on any backend
    elif not use_fused:
        # GNNModel._aggregate routes to the gather-scatter baseline
        agg_primitive = "gather.segment_sum_baseline"
    elif emit_epilogue:
        agg_primitive = f"{backend.name}.spmm_fused_epilogue"
    else:
        agg_primitive = f"{backend.name}.spmm_transposed_vjp"

    s_input = 0.0
    if features is not None:
        features = np.asarray(features)

    layers: list[LayerPlan] = []
    for i in range(config.n_layers):
        d_in, d_out = dims[i], dims[i + 1]
        if i == 0:
            if features is not None:
                decision = decide_execution_path(
                    features, gamma=gamma, n_hidden=d_out)
                s_input = decision.sparsity
            else:
                decision = decide_execution_path_from_stats(
                    0.0, n_nodes, d_in, d_out, gamma=gamma)
        else:
            s_est = estimate_activation_sparsity(config.activation)
            decision = decide_execution_path_from_stats(
                s_est, n_nodes, d_in, d_out, gamma=gamma)

        sparse_xw = None
        note = ""
        if decision.mode == "sparse":
            expressible, expr_note = _sparse_expressible(kind)
            if i == 0 and features is not None and use_fused and expressible:
                # operand of the (possibly reordered) feature matrix; bc
                # adapts to the feature dim — X's columns are features, not
                # graph nodes, so the adjacency tile does not apply
                sparse_xw = backend.feature_matmul_sparse(
                    features_exec, br=lp.br, bc=None, interpret=interpret)
                path = "sparse"
                primitive = f"{backend.name}.feature_matmul_sparse"
                note = expr_note
            else:
                path = "dense"
                primitive = f"{backend.name}.feature_matmul_dense"
                if not use_fused:
                    note = "sparse profitable but fusion disabled (use_fused=False)"
                elif i > 0:
                    note = ("sparse profitable but activations are runtime "
                            "values; no pre-built operand — dense fallback")
                elif features is None:
                    note = "feature matrix unknown at lowering time"
                else:
                    note = expr_note
        else:
            path = "dense"
            primitive = f"{backend.name}.feature_matmul_dense"

        epilogue = None
        if emit_epilogue:
            epilogue = _epilogue_binding(
                config, is_last=(i == config.n_layers - 1),
                sparse_path=sparse_xw is not None)
        attention = None
        if is_attn:
            attention = _attention_binding(config.gat_heads, d_out,
                                           attn_bound)

        layers.append(LayerPlan(
            index=i, op_kind=kind, d_in=d_in, d_out=d_out,
            feature_path=path, primitive=primitive,
            agg_primitive=agg_primitive, decision=decision,
            sparse_xw=sparse_xw, note=note, epilogue=epilogue,
            attention=attention, layout=lp,
        ))

    plan = ModelPlan(
        layers=layers, backend=backend.name, gamma=gamma, arch=kind,
        aggregation=agg, feature_sparsity=s_input, graph_op=graph_op,
        layout=lp,
    )
    check_plan(plan, mode=validate, graph=graph_exec)
    return plan
