"""Distributed GNN runtime — the JAX/TPU analog of the paper's MPI backend.

Paper §IV-E2 maps as follows:

* **G2L contiguous layout**: each rank's feature buffer is
  ``[local_nodes | ghost_nodes]`` — local slots [0, n_local) followed by
  ghosts, so kernels see dense index ranges (identical to the paper's
  layout enabling AVX on local tensors; here it enables one BSR over the
  concatenated buffer).
* **Asynchronous halo exchange** (MPI_Isend/Irecv): ``ppermute`` rounds over
  ring shifts. XLA's latency-hiding scheduler overlaps the collective DMA
  with independent compute, which is the paper's parallel-pack /
  non-blocking-issue / wait-free-unpack protocol expressed declaratively.
* **BSP step**: one jitted shard_map program per training step; the jit
  boundary is the barrier.

Everything here is SPMD-uniform: per-rank structures are padded to fleet
maxima and stacked on a leading rank axis, which is what makes the same
program runnable on 8 CPU host-devices in tests and 512 TPU chips in the
dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import axis_size as compat_axis_size

from repro.core.partitioner import PartitionResult, build_local_views
from repro.graph.csr import CSRGraph, csr_from_edges, csr_to_bsr


def _ceil_to(x: int, m: int) -> int:
    return max(-(-x // m) * m, m)


@dataclasses.dataclass
class DistributedGraph:
    """Host-built SPMD plan: stacked per-rank BSR + halo schedules.

    When built with ``split_phase=True`` (the default) the forward operand
    is additionally split per rank into an *interior* operand — block-rows
    whose columns are all local, runnable while the halo exchange is still
    in flight — and a *boundary* operand — block-rows that may read ghost
    columns — each with its transpose for the overlapped backward
    (DESIGN.md §11). Both split streams cover every local block-row with
    explicit zero blocks (the Pallas kernel's row-coverage contract), so
    ``y = y_interior + y_boundary`` stitches rows back exactly.
    """

    n_ranks: int
    n_local: int  # padded, uniform across ranks, multiple of 128
    n_ghost: int  # padded, uniform, multiple of 128
    max_send: int
    # stacked fwd BSR of local graphs: rows=[local], cols=[local|ghost]
    fwd: dict  # rows/cols/first [P, B], blocks [P, B, br, bc]
    bwd: dict  # BSR of transpose: rows=[local|ghost], cols=[local]
    send_idx: np.ndarray  # [P, P-1, max_send] local idx to send at shift s (-1 pad)
    recv_slot: np.ndarray  # [P, P-1, max_send] ghost slot (0-based in ghost region)
    features: np.ndarray  # [P, n_local, F]
    labels: np.ndarray  # [P, n_local]
    mask: np.ndarray  # [P, n_local] bool (False on padding)
    br: int
    bc: int
    # per-rank unpadded node counts — the lowering pass's per-rank Alg-1
    # statistics are computed over these rows only (padding is all-zero)
    n_valid: Optional[np.ndarray] = None  # [P] int32
    # stacked local edge lists (src indexes [local|ghost] slots, dst local
    # rows; -1 padded) — the segment path for GAT edge-softmax / max agg
    edge_src: Optional[np.ndarray] = None  # [P, max_edges] int32
    edge_dst: Optional[np.ndarray] = None  # [P, max_edges] int32
    aggregation: str = "sum"  # weighting applied to the local adjacencies
    # within-rank node order the local views were built with ("none" |
    # "degree" | "rcm") — recorded so lower_distributed's LayoutPlan can
    # say what layout the stacked operands carry
    reorder: str = "none"
    # -- split-phase operands (None when built with split_phase=False) -----
    # interior: rows=[local], cols=[local] only; boundary: rows=[local],
    # cols=[local|ghost]. Each stream covers all local block-rows.
    fwd_interior: Optional[dict] = None
    bwd_interior: Optional[dict] = None  # transpose: [local] x [local]
    fwd_boundary: Optional[dict] = None
    bwd_boundary: Optional[dict] = None  # transpose: [local|ghost] x [local]
    n_interior: Optional[np.ndarray] = None  # [P] leading interior local slots
    interior_blocks: Optional[np.ndarray] = None  # [P] per-rank stream length
    boundary_blocks: Optional[np.ndarray] = None  # [P]
    # ring shifts with at least one live (send_idx >= 0) entry on any rank;
    # a ppermute is collective, so the set is any-over-ranks (host-computed)
    live_shifts: Optional[tuple] = None

    def __post_init__(self):
        split = [self.fwd_interior, self.bwd_interior,
                 self.fwd_boundary, self.bwd_boundary]
        if any(s is not None for s in split):
            if any(s is None for s in split):
                raise ValueError(
                    "split-phase operands must be constructed together "
                    "(fwd/bwd x interior/boundary)")
            nrb = self.n_local // self.br
            ncb_local = self.n_local // self.bc
            if int(self.fwd_interior["cols"].max(initial=0)) >= ncb_local:
                raise ValueError(
                    "interior operand references a ghost column: "
                    f"max block-col {int(self.fwd_interior['cols'].max())} "
                    f">= {ncb_local}")
            if int(self.fwd_interior["rows"].max(initial=0)) >= nrb:
                raise ValueError("interior operand row outside local region")
            if int(self.fwd_boundary["rows"].max(initial=0)) >= nrb:
                raise ValueError("boundary operand row outside local region")
            if (self.n_interior is not None and self.n_valid is not None
                    and bool((np.asarray(self.n_interior)
                              > np.asarray(self.n_valid)).any())):
                raise ValueError("n_interior exceeds per-rank valid rows")
        if self.live_shifts is not None:
            bad = [s for s in self.live_shifts
                   if not 1 <= int(s) < max(self.n_ranks, 2)]
            if bad:
                raise ValueError(f"live shifts {bad} outside [1, P)")


def stack_bsr_matrices(bsrs, br: int, bc: int) -> dict:
    """Stack per-rank BSR matrices on a leading rank axis, padded to the
    fleet-max block count (zero blocks accumulate 0 into the last row)."""
    P = len(bsrs)
    n_blocks = max(b.n_blocks for b in bsrs)
    rows = np.zeros((P, n_blocks), dtype=np.int32)
    cols = np.zeros((P, n_blocks), dtype=np.int32)
    first = np.zeros((P, n_blocks), dtype=np.int32)
    blocks = np.zeros((P, n_blocks, br, bc), dtype=np.float32)
    for p, b in enumerate(bsrs):
        k = b.n_blocks
        rows[p, :k] = b.block_rows
        cols[p, :k] = b.block_cols
        first[p, :k] = b.first_in_row
        blocks[p, :k] = b.blocks
        if k < n_blocks:  # zero-block padding accumulates 0 into last row
            rows[p, k:] = b.block_rows[-1] if k else 0
            cols[p, k:] = 0
    return {"rows": rows, "cols": cols, "first": first, "blocks": blocks}


def build_distributed_graph(
    graph: CSRGraph,
    features: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
    partition: PartitionResult,
    br: int = 8,
    bc: int = 128,
    aggregation: str = "sum",
    reorder: str = "none",
    split_phase: bool = True,
) -> DistributedGraph:
    """Build the SPMD plan. ``aggregation`` weights the *global* adjacency
    (``"sum"`` keeps it raw — pass pre-weighted graphs that way) before the
    per-rank views are cut, so degree normalisation sees global degrees.
    ``reorder`` renumbers each rank's local block (degree / RCM on the
    rank's induced subgraph) before the per-rank BSR is materialised —
    denser local blocks, no semantic change (the halo schedule and the
    feature/label/mask stacking all follow the permuted ``global_ids``).

    ``split_phase`` additionally splits each rank's forward operand by
    block-row into interior (all columns local) / boundary (may read ghost
    columns) streams, with transposes, and computes the live ring-shift set
    — the operands of the overlapped runtime (DESIGN.md §11). The bulk
    ``fwd``/``bwd`` pair is always built; ``split_phase=False`` is the
    fallback that skips the extra streams."""
    if aggregation != "sum":
        from repro.core.aggregate import _weighted_graph

        graph = _weighted_graph(graph, aggregation)
    P = partition.k
    views = build_local_views(graph, partition.assignment, P, reorder=reorder)
    n_local = _ceil_to(max(v.n_local for v in views), bc)
    n_ghost = _ceil_to(max(max(v.n_ghost for v in views), 1), bc)

    f_dim = features.shape[1]
    feats = np.zeros((P, n_local, f_dim), dtype=np.float32)
    labs = np.zeros((P, n_local), dtype=np.int32)
    mask = np.zeros((P, n_local), dtype=bool)

    # -- halo schedule: for ring shift s, rank r sends to (r+s)%P ----------
    # pair_nodes[(o, r)] = ordered list of global ids owner o sends to r
    pair_nodes: dict[tuple[int, int], list[int]] = {}
    for v in views:
        for slot, (gid, owner) in enumerate(
            zip(v.global_ids[v.n_local:], v.ghost_owner)
        ):
            pair_nodes.setdefault((int(owner), v.rank), []).append(int(gid))
    max_send = max((len(v) for v in pair_nodes.values()), default=1)
    send_idx = np.full((P, P - 1, max_send), -1, dtype=np.int32)
    recv_slot = np.full((P, P - 1, max_send), -1, dtype=np.int32)

    g2l_local = []  # global -> local index among owned nodes, per rank
    for v in views:
        g2l_local.append({int(g): i for i, g in enumerate(v.global_ids[: v.n_local])})
    ghost_slot_of = []  # global -> slot within ghost region, per rank
    for v in views:
        ghost_slot_of.append(
            {int(g): i for i, g in enumerate(v.global_ids[v.n_local:])}
        )

    for (o, r), nodes in pair_nodes.items():
        s = (r - o) % P
        assert s != 0
        for j, gid in enumerate(nodes):
            send_idx[o, s - 1, j] = g2l_local[o][gid]
            recv_slot[r, s - 1, j] = ghost_slot_of[r][gid]

    # -- per-rank local BSR (padded coords) + local COO edge lists ---------
    fwd_stack, bwd_stack = [], []
    int_fwd, int_bwd, bnd_fwd, bnd_bwd = [], [], [], []
    edge_lists: list[tuple[np.ndarray, np.ndarray]] = []
    for v in views:
        # remap ghost columns from (v.n_local + j) to (n_local + j)
        src, dst = v.local_graph.edge_list()
        src = src.astype(np.int64)
        dst = dst.astype(np.int64)
        ghost_sel = src >= v.n_local
        src[ghost_sel] = src[ghost_sel] - v.n_local + n_local
        lg = csr_from_edges(
            src=src, dst=dst, n_rows=n_local, n_cols=n_local + n_ghost,
            data=v.local_graph.data, dedupe=False,
        )
        fwd_stack.append(csr_to_bsr(lg, br=br, bc=bc))
        bwd_stack.append(csr_to_bsr(lg.transpose(), br=br, bc=bc))
        edge_lists.append((src.astype(np.int32), dst.astype(np.int32)))
        feats[v.rank, : v.n_local] = features[v.global_ids[: v.n_local]]
        labs[v.rank, : v.n_local] = labels[v.global_ids[: v.n_local]]
        mask[v.rank, : v.n_local] = train_mask[v.global_ids[: v.n_local]]

        if split_phase:
            # block-row granularity split: a block-row is boundary iff any
            # of its edges reads a ghost column. The [interior | boundary]
            # node order of build_local_views confines mixing to at most
            # the one block-row straddling the segment boundary.
            nrb = n_local // br
            boundary_row = np.zeros(nrb, dtype=bool)
            boundary_row[(dst[ghost_sel] // br)] = True
            eb = boundary_row[dst // br]
            ipair, bpair = _split_pair(
                src, dst, np.asarray(v.local_graph.data), eb,
                n_local, n_ghost, br, bc)
            int_fwd.append(ipair[0])
            int_bwd.append(ipair[1])
            bnd_fwd.append(bpair[0])
            bnd_bwd.append(bpair[1])

    max_edges = max(max(len(s) for s, _ in edge_lists), 1)
    edge_src = np.full((P, max_edges), -1, dtype=np.int32)
    edge_dst = np.full((P, max_edges), -1, dtype=np.int32)
    for p, (s, d) in enumerate(edge_lists):
        edge_src[p, : len(s)] = s
        edge_dst[p, : len(d)] = d

    live_shifts = tuple(
        int(s) for s in range(1, P) if bool((send_idx[:, s - 1] >= 0).any()))

    split_kw = {}
    if split_phase:
        split_kw = dict(
            fwd_interior=stack_bsr_matrices(int_fwd, br, bc),
            bwd_interior=stack_bsr_matrices(int_bwd, br, bc),
            fwd_boundary=stack_bsr_matrices(bnd_fwd, br, bc),
            bwd_boundary=stack_bsr_matrices(bnd_bwd, br, bc),
            n_interior=np.asarray([v.n_interior for v in views],
                                  dtype=np.int32),
            interior_blocks=np.asarray([b.n_blocks for b in int_fwd],
                                       dtype=np.int64),
            boundary_blocks=np.asarray([b.n_blocks for b in bnd_fwd],
                                       dtype=np.int64),
        )

    return DistributedGraph(
        n_ranks=P, n_local=n_local, n_ghost=n_ghost, max_send=max_send,
        fwd=stack_bsr_matrices(fwd_stack, br, bc),
        bwd=stack_bsr_matrices(bwd_stack, br, bc),
        send_idx=send_idx, recv_slot=recv_slot,
        features=feats, labels=labs, mask=mask, br=br, bc=bc,
        n_valid=np.asarray([v.n_local for v in views], dtype=np.int32),
        edge_src=edge_src, edge_dst=edge_dst, aggregation=aggregation,
        reorder=reorder, live_shifts=live_shifts, **split_kw,
    )


def _empty_csr(n_rows: int, n_cols: int) -> CSRGraph:
    return CSRGraph(
        indptr=np.zeros(n_rows + 1, dtype=np.int64),
        indices=np.zeros(0, dtype=np.int32),
        data=np.zeros(0, dtype=np.float32),
        n_rows=n_rows, n_cols=n_cols,
    )


def _split_pair(src, dst, data, boundary_edge, n_local, n_ghost, br, bc):
    """Cut one rank's edge set into interior / boundary CSR→BSR pairs.

    Both streams span all ``n_local`` rows — ``csr_to_bsr`` inserts an
    explicit zero block for every uncovered block-row (the kernel's
    row-coverage contract), so the two partial SpMMs add back to the bulk
    result row-exactly. The interior operand's column space is local-only
    (``n_cols = n_local``): its SpMM consumes no ghost slot and therefore
    never waits on the halo exchange."""
    def one(sel, n_cols):
        if sel.any():
            csr = csr_from_edges(
                src=src[sel], dst=dst[sel], n_rows=n_local, n_cols=n_cols,
                data=data[sel], dedupe=False)
        else:
            csr = _empty_csr(n_local, n_cols)
        return (csr_to_bsr(csr, br=br, bc=bc),
                csr_to_bsr(csr.transpose(), br=br, bc=bc))

    return one(~boundary_edge, n_local), one(boundary_edge, n_local + n_ghost)


# ---------------------------------------------------------------------------
# In-step primitives (run inside shard_map, per-rank views)
# ---------------------------------------------------------------------------

def _norm_shifts(shifts) -> Optional[tuple]:
    """Normalise a live-shift set to a hashable tuple (None = all P-1)."""
    if shifts is None:
        return None
    return tuple(int(s) for s in shifts)


def _halo_exchange_impl(
    x_local: jax.Array,  # [n_local, F]
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_ghost: int,
    axis_name: str,
    shifts: Optional[tuple] = None,
) -> jax.Array:
    """Raw exchange body — a linear map of ``x_local`` (gather, ppermute,
    scatter-add are all linear), kept un-wrapped so tests can take its
    ``jax.linear_transpose`` and compare against ``halo_exchange_transpose``.

    ``shifts`` restricts the unrolled ring shifts to the given live set
    (host-computed in ``build_distributed_graph``); a shift whose
    ``send_idx`` row is all -1 on *every* rank exchanges nothing, so
    skipping it is exact. ``None`` issues all P-1 shifts."""
    P = compat_axis_size(axis_name)
    f = x_local.shape[-1]
    ghost = jnp.zeros((n_ghost, f), dtype=x_local.dtype)
    for s in (range(1, P) if shifts is None else shifts):
        idx = send_idx[s - 1]
        valid_send = (idx >= 0)[:, None]
        payload = jnp.where(valid_send, x_local[jnp.clip(idx, 0), :], 0)
        perm = [(r, (r + s) % P) for r in range(P)]
        received = jax.lax.ppermute(payload, axis_name, perm)
        slot = recv_slot[s - 1]
        valid_recv = (slot >= 0)[:, None]
        ghost = ghost.at[jnp.clip(slot, 0)].add(
            jnp.where(valid_recv, received, 0)
        )
    return ghost


def halo_exchange_debug(
    x_local: jax.Array,  # [n_local, F]
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_ghost: int,
    axis_name: str,
    shifts: Optional[tuple] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``_halo_exchange_impl`` plus a transit checksum (DESIGN.md §14).

    Returns ``(ghost, shipped, received)`` where the two scalars are
    position-and-shift-weighted sums of the valid payload rows, psum'd
    over the mesh. ``ppermute`` preserves send-buffer position end to
    end, so the weighting detects payload corruption, a valid-mask
    (send/recv schedule) mismatch, and shift desync — a plain sum would
    miss the row-for-row swaps the position weights catch. It does *not*
    detect misrouting among valid ghost slots (a corrupted ``recv_slot``
    value routing a row to a different valid slot leaves both sums
    equal, since ``received`` is summed before the ghost scatter); that
    class is covered by the static ``halo.slot_unique`` /
    ``halo.schedule_paired`` checks in ``core/verify.py``. The host-side
    ``debug_halo_check`` turns a nonzero difference into an error.
    """
    P = compat_axis_size(axis_name)
    f = x_local.shape[-1]
    ghost = jnp.zeros((n_ghost, f), dtype=x_local.dtype)
    shipped = jnp.zeros((), jnp.float32)
    received_sum = jnp.zeros((), jnp.float32)
    for s in (range(1, P) if shifts is None else shifts):
        idx = send_idx[s - 1]
        valid_send = (idx >= 0)[:, None]
        payload = jnp.where(valid_send, x_local[jnp.clip(idx, 0), :], 0)
        w = (jnp.arange(payload.shape[0], dtype=jnp.float32) + 1.0) * float(s)
        shipped = shipped + (
            payload.astype(jnp.float32).sum(axis=-1) * w).sum()
        perm = [(r, (r + s) % P) for r in range(P)]
        received = jax.lax.ppermute(payload, axis_name, perm)
        slot = recv_slot[s - 1]
        valid_recv = (slot >= 0)[:, None]
        kept = jnp.where(valid_recv, received, 0)
        received_sum = received_sum + (
            kept.astype(jnp.float32).sum(axis=-1) * w).sum()
        ghost = ghost.at[jnp.clip(slot, 0)].add(kept)
    shipped = jax.lax.psum(shipped, axis_name)
    received_sum = jax.lax.psum(received_sum, axis_name)
    return ghost, shipped, received_sum


def halo_exchange_transpose(
    ghost: jax.Array,  # [n_ghost, F] ghost-slot cotangents
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_local: int,
    axis_name: str,
    shifts: Optional[tuple] = None,
) -> jax.Array:
    """The linear transpose of ``_halo_exchange_impl``: ghost-slot values
    return to their owning ranks. Each shift transposes gather/ppermute/
    scatter into scatter/reverse-ppermute/gather — the reverse exchange the
    backward pass issues for ghost gradients. ``shifts`` mirrors the
    forward's live-shift set (a dead forward shift is dead in reverse)."""
    P = compat_axis_size(axis_name)
    out = jnp.zeros((n_local, ghost.shape[-1]), dtype=ghost.dtype)
    for s in (range(1, P) if shifts is None else shifts):
        slot = recv_slot[s - 1]
        valid = (slot >= 0)[:, None]
        payload = jnp.where(valid, ghost[jnp.clip(slot, 0), :], 0)
        perm = [((r + s) % P, r) for r in range(P)]  # reverse direction
        received = jax.lax.ppermute(payload, axis_name, perm)
        idx = send_idx[s - 1]
        valid_r = (idx >= 0)[:, None]
        out = out.at[jnp.clip(idx, 0)].add(jnp.where(valid_r, received, 0))
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _halo_exchange_vjp(
    x_local: jax.Array,  # [n_local, F]
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_ghost: int,
    axis_name: str,
    shifts: Optional[tuple],
) -> jax.Array:
    return _halo_exchange_impl(
        x_local, send_idx, recv_slot, n_ghost, axis_name, shifts)


def _halo_fwd(x_local, send_idx, recv_slot, n_ghost, axis_name, shifts):
    ghost = _halo_exchange_impl(
        x_local, send_idx, recv_slot, n_ghost, axis_name, shifts)
    return ghost, (send_idx, recv_slot, x_local.shape[0])


def _halo_bwd(n_ghost, axis_name, shifts, res, g):
    send_idx, recv_slot, n_local = res
    dx = halo_exchange_transpose(
        g, send_idx, recv_slot, n_local, axis_name, shifts)
    # integer schedule arrays carry symbolic-zero (float0) cotangents
    zero = lambda a: np.zeros(a.shape, dtype=jax.dtypes.float0)
    return dx, zero(send_idx), zero(recv_slot)


_halo_exchange_vjp.defvjp(_halo_fwd, _halo_bwd)


def halo_exchange(
    x_local: jax.Array,  # [n_local, F]
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_ghost: int,
    axis_name: str,
    shifts=None,
) -> jax.Array:
    """Ghost-feature exchange: returns [n_ghost, F].

    Each ring shift is: pack (gather) -> ppermute -> unpack (scatter). The
    packs of shift s+1 are independent of the unpacks of shift s, so XLA
    overlaps communication with the next round's packing — the paper's
    split-phase protocol. The custom VJP pins the backward pass to
    ``halo_exchange_transpose`` (the explicit reverse schedule), so ghost
    gradients return to owners without autodiff re-deriving the exchange.

    ``shifts`` unrolls only the given live ring shifts (see
    ``DistributedGraph.live_shifts``); ``None`` issues all P-1.
    """
    return _halo_exchange_vjp(
        x_local, send_idx, recv_slot, n_ghost, axis_name,
        _norm_shifts(shifts))


class GhostBufferRing:
    """Static double-buffer schedule for per-layer ghost buffers.

    Under XLA's SSA program form there is no mutable buffer to rotate —
    each layer's ghost tensor is a fresh value. What the ring encodes is
    the *allocation contract*: consecutive layers draw from distinct slots
    of an ``n_slots``-deep pool, so layer k+1's exchange never has a
    write-after-read hazard on layer k's ghost value and buffer assignment
    is free to keep both live while the collectives overlap. The trainer
    acquires one slot per layer at trace time; ``schedule()`` exposes the
    rotation for plan dumps and tests (DESIGN.md §11).
    """

    def __init__(self, n_slots: int = 2):
        if n_slots < 2:
            raise ValueError("double buffering needs at least 2 slots")
        self.n_slots = int(n_slots)
        self._schedule: list[int] = []

    def acquire(self, layer: int) -> int:
        slot = int(layer) % self.n_slots
        if self._schedule and self._schedule[-1] == slot:
            raise ValueError(
                f"slot {slot} acquired twice in a row — adjacent layers "
                f"must rotate ghost buffers")
        self._schedule.append(slot)
        return slot

    def schedule(self) -> tuple:
        return tuple(self._schedule)


# The fused local aggregation over the contiguous [local|ghost] buffer now
# lives in ``backends/distributed.py`` (``dist_spmm[_transposed_vjp]``),
# composed from ``halo_exchange`` + ``kernels.ops.bsr_spmm_pair`` — the
# distributed backend owns the composition, this module owns the exchange.
