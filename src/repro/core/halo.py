"""Distributed GNN runtime — the JAX/TPU analog of the paper's MPI backend.

Paper §IV-E2 maps as follows:

* **G2L contiguous layout**: each rank's feature buffer is
  ``[local_nodes | ghost_nodes]`` — local slots [0, n_local) followed by
  ghosts, so kernels see dense index ranges (identical to the paper's
  layout enabling AVX on local tensors; here it enables one BSR over the
  concatenated buffer).
* **Asynchronous halo exchange** (MPI_Isend/Irecv): ``ppermute`` rounds over
  ring shifts. XLA's latency-hiding scheduler overlaps the collective DMA
  with independent compute, which is the paper's parallel-pack /
  non-blocking-issue / wait-free-unpack protocol expressed declaratively.
* **BSP step**: one jitted shard_map program per training step; the jit
  boundary is the barrier.

Everything here is SPMD-uniform: per-rank structures are padded to fleet
maxima and stacked on a leading rank axis, which is what makes the same
program runnable on 8 CPU host-devices in tests and 512 TPU chips in the
dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import axis_size as compat_axis_size

from repro.core.partitioner import PartitionResult, build_local_views
from repro.graph.csr import CSRGraph, csr_from_edges, csr_to_bsr


def _ceil_to(x: int, m: int) -> int:
    return max(-(-x // m) * m, m)


@dataclasses.dataclass
class DistributedGraph:
    """Host-built SPMD plan: stacked per-rank BSR + halo schedules."""

    n_ranks: int
    n_local: int  # padded, uniform across ranks, multiple of 128
    n_ghost: int  # padded, uniform, multiple of 128
    max_send: int
    # stacked fwd BSR of local graphs: rows=[local], cols=[local|ghost]
    fwd: dict  # rows/cols/first [P, B], blocks [P, B, br, bc]
    bwd: dict  # BSR of transpose: rows=[local|ghost], cols=[local]
    send_idx: np.ndarray  # [P, P-1, max_send] local idx to send at shift s (-1 pad)
    recv_slot: np.ndarray  # [P, P-1, max_send] ghost slot (0-based in ghost region)
    features: np.ndarray  # [P, n_local, F]
    labels: np.ndarray  # [P, n_local]
    mask: np.ndarray  # [P, n_local] bool (False on padding)
    br: int
    bc: int
    # per-rank unpadded node counts — the lowering pass's per-rank Alg-1
    # statistics are computed over these rows only (padding is all-zero)
    n_valid: np.ndarray = None  # [P] int32
    # stacked local edge lists (src indexes [local|ghost] slots, dst local
    # rows; -1 padded) — the segment path for GAT edge-softmax / max agg
    edge_src: np.ndarray = None  # [P, max_edges] int32
    edge_dst: np.ndarray = None  # [P, max_edges] int32
    aggregation: str = "sum"  # weighting applied to the local adjacencies
    # within-rank node order the local views were built with ("none" |
    # "degree" | "rcm") — recorded so lower_distributed's LayoutPlan can
    # say what layout the stacked operands carry
    reorder: str = "none"


def stack_bsr_matrices(bsrs, br: int, bc: int) -> dict:
    """Stack per-rank BSR matrices on a leading rank axis, padded to the
    fleet-max block count (zero blocks accumulate 0 into the last row)."""
    P = len(bsrs)
    n_blocks = max(b.n_blocks for b in bsrs)
    rows = np.zeros((P, n_blocks), dtype=np.int32)
    cols = np.zeros((P, n_blocks), dtype=np.int32)
    first = np.zeros((P, n_blocks), dtype=np.int32)
    blocks = np.zeros((P, n_blocks, br, bc), dtype=np.float32)
    for p, b in enumerate(bsrs):
        k = b.n_blocks
        rows[p, :k] = b.block_rows
        cols[p, :k] = b.block_cols
        first[p, :k] = b.first_in_row
        blocks[p, :k] = b.blocks
        if k < n_blocks:  # zero-block padding accumulates 0 into last row
            rows[p, k:] = b.block_rows[-1]
            cols[p, k:] = 0
    return {"rows": rows, "cols": cols, "first": first, "blocks": blocks}


def build_distributed_graph(
    graph: CSRGraph,
    features: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
    partition: PartitionResult,
    br: int = 8,
    bc: int = 128,
    aggregation: str = "sum",
    reorder: str = "none",
) -> DistributedGraph:
    """Build the SPMD plan. ``aggregation`` weights the *global* adjacency
    (``"sum"`` keeps it raw — pass pre-weighted graphs that way) before the
    per-rank views are cut, so degree normalisation sees global degrees.
    ``reorder`` renumbers each rank's local block (degree / RCM on the
    rank's induced subgraph) before the per-rank BSR is materialised —
    denser local blocks, no semantic change (the halo schedule and the
    feature/label/mask stacking all follow the permuted ``global_ids``)."""
    if aggregation != "sum":
        from repro.core.aggregate import _weighted_graph

        graph = _weighted_graph(graph, aggregation)
    P = partition.k
    views = build_local_views(graph, partition.assignment, P, reorder=reorder)
    n_local = _ceil_to(max(v.n_local for v in views), bc)
    n_ghost = _ceil_to(max(max(v.n_ghost for v in views), 1), bc)

    f_dim = features.shape[1]
    feats = np.zeros((P, n_local, f_dim), dtype=np.float32)
    labs = np.zeros((P, n_local), dtype=np.int32)
    mask = np.zeros((P, n_local), dtype=bool)

    # -- halo schedule: for ring shift s, rank r sends to (r+s)%P ----------
    # pair_nodes[(o, r)] = ordered list of global ids owner o sends to r
    pair_nodes: dict[tuple[int, int], list[int]] = {}
    for v in views:
        for slot, (gid, owner) in enumerate(
            zip(v.global_ids[v.n_local:], v.ghost_owner)
        ):
            pair_nodes.setdefault((int(owner), v.rank), []).append(int(gid))
    max_send = max((len(v) for v in pair_nodes.values()), default=1)
    send_idx = np.full((P, P - 1, max_send), -1, dtype=np.int32)
    recv_slot = np.full((P, P - 1, max_send), -1, dtype=np.int32)

    g2l_local = []  # global -> local index among owned nodes, per rank
    for v in views:
        g2l_local.append({int(g): i for i, g in enumerate(v.global_ids[: v.n_local])})
    ghost_slot_of = []  # global -> slot within ghost region, per rank
    for v in views:
        ghost_slot_of.append(
            {int(g): i for i, g in enumerate(v.global_ids[v.n_local:])}
        )

    for (o, r), nodes in pair_nodes.items():
        s = (r - o) % P
        assert s != 0
        for j, gid in enumerate(nodes):
            send_idx[o, s - 1, j] = g2l_local[o][gid]
            recv_slot[r, s - 1, j] = ghost_slot_of[r][gid]

    # -- per-rank local BSR (padded coords) + local COO edge lists ---------
    fwd_stack, bwd_stack = [], []
    edge_lists: list[tuple[np.ndarray, np.ndarray]] = []
    for v in views:
        # remap ghost columns from (v.n_local + j) to (n_local + j)
        src, dst = v.local_graph.edge_list()
        src = src.astype(np.int64)
        ghost_sel = src >= v.n_local
        src[ghost_sel] = src[ghost_sel] - v.n_local + n_local
        lg = csr_from_edges(
            src=src, dst=dst, n_rows=n_local, n_cols=n_local + n_ghost,
            data=v.local_graph.data, dedupe=False,
        )
        fwd_stack.append(csr_to_bsr(lg, br=br, bc=bc))
        bwd_stack.append(csr_to_bsr(lg.transpose(), br=br, bc=bc))
        edge_lists.append((src.astype(np.int32), dst.astype(np.int32)))
        feats[v.rank, : v.n_local] = features[v.global_ids[: v.n_local]]
        labs[v.rank, : v.n_local] = labels[v.global_ids[: v.n_local]]
        mask[v.rank, : v.n_local] = train_mask[v.global_ids[: v.n_local]]

    max_edges = max(max(len(s) for s, _ in edge_lists), 1)
    edge_src = np.full((P, max_edges), -1, dtype=np.int32)
    edge_dst = np.full((P, max_edges), -1, dtype=np.int32)
    for p, (s, d) in enumerate(edge_lists):
        edge_src[p, : len(s)] = s
        edge_dst[p, : len(d)] = d

    return DistributedGraph(
        n_ranks=P, n_local=n_local, n_ghost=n_ghost, max_send=max_send,
        fwd=stack_bsr_matrices(fwd_stack, br, bc),
        bwd=stack_bsr_matrices(bwd_stack, br, bc),
        send_idx=send_idx, recv_slot=recv_slot,
        features=feats, labels=labs, mask=mask, br=br, bc=bc,
        n_valid=np.asarray([v.n_local for v in views], dtype=np.int32),
        edge_src=edge_src, edge_dst=edge_dst, aggregation=aggregation,
        reorder=reorder,
    )


# ---------------------------------------------------------------------------
# In-step primitives (run inside shard_map, per-rank views)
# ---------------------------------------------------------------------------

def _halo_exchange_impl(
    x_local: jax.Array,  # [n_local, F]
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_ghost: int,
    axis_name: str,
) -> jax.Array:
    """Raw exchange body — a linear map of ``x_local`` (gather, ppermute,
    scatter-add are all linear), kept un-wrapped so tests can take its
    ``jax.linear_transpose`` and compare against ``halo_exchange_transpose``."""
    P = compat_axis_size(axis_name)
    f = x_local.shape[-1]
    ghost = jnp.zeros((n_ghost, f), dtype=x_local.dtype)
    for s in range(1, P):
        idx = send_idx[s - 1]
        valid_send = (idx >= 0)[:, None]
        payload = jnp.where(valid_send, x_local[jnp.clip(idx, 0), :], 0)
        perm = [(r, (r + s) % P) for r in range(P)]
        received = jax.lax.ppermute(payload, axis_name, perm)
        slot = recv_slot[s - 1]
        valid_recv = (slot >= 0)[:, None]
        ghost = ghost.at[jnp.clip(slot, 0)].add(
            jnp.where(valid_recv, received, 0)
        )
    return ghost


def halo_exchange_transpose(
    ghost: jax.Array,  # [n_ghost, F] ghost-slot cotangents
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_local: int,
    axis_name: str,
) -> jax.Array:
    """The linear transpose of ``_halo_exchange_impl``: ghost-slot values
    return to their owning ranks. Each shift transposes gather/ppermute/
    scatter into scatter/reverse-ppermute/gather — the reverse exchange the
    backward pass issues for ghost gradients."""
    P = compat_axis_size(axis_name)
    out = jnp.zeros((n_local, ghost.shape[-1]), dtype=ghost.dtype)
    for s in range(1, P):
        slot = recv_slot[s - 1]
        valid = (slot >= 0)[:, None]
        payload = jnp.where(valid, ghost[jnp.clip(slot, 0), :], 0)
        perm = [((r + s) % P, r) for r in range(P)]  # reverse direction
        received = jax.lax.ppermute(payload, axis_name, perm)
        idx = send_idx[s - 1]
        valid_r = (idx >= 0)[:, None]
        out = out.at[jnp.clip(idx, 0)].add(jnp.where(valid_r, received, 0))
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def halo_exchange(
    x_local: jax.Array,  # [n_local, F]
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_ghost: int,
    axis_name: str,
) -> jax.Array:
    """Ghost-feature exchange: returns [n_ghost, F].

    Each ring shift is: pack (gather) -> ppermute -> unpack (scatter). The
    packs of shift s+1 are independent of the unpacks of shift s, so XLA
    overlaps communication with the next round's packing — the paper's
    split-phase protocol. The custom VJP pins the backward pass to
    ``halo_exchange_transpose`` (the explicit reverse schedule), so ghost
    gradients return to owners without autodiff re-deriving the exchange.
    """
    return _halo_exchange_impl(x_local, send_idx, recv_slot, n_ghost, axis_name)


def _halo_fwd(x_local, send_idx, recv_slot, n_ghost, axis_name):
    ghost = _halo_exchange_impl(x_local, send_idx, recv_slot, n_ghost, axis_name)
    return ghost, (send_idx, recv_slot, x_local.shape[0])


def _halo_bwd(n_ghost, axis_name, res, g):
    send_idx, recv_slot, n_local = res
    dx = halo_exchange_transpose(g, send_idx, recv_slot, n_local, axis_name)
    # integer schedule arrays carry symbolic-zero (float0) cotangents
    zero = lambda a: np.zeros(a.shape, dtype=jax.dtypes.float0)
    return dx, zero(send_idx), zero(recv_slot)


halo_exchange.defvjp(_halo_fwd, _halo_bwd)


# The fused local aggregation over the contiguous [local|ghost] buffer now
# lives in ``backends/distributed.py`` (``dist_spmm[_transposed_vjp]``),
# composed from ``halo_exchange`` + ``kernels.ops.bsr_spmm_pair`` — the
# distributed backend owns the composition, this module owns the exchange.
