"""Distributed GNN runtime — the JAX/TPU analog of the paper's MPI backend.

Paper §IV-E2 maps as follows:

* **G2L contiguous layout**: each rank's feature buffer is
  ``[local_nodes | ghost_nodes]`` — local slots [0, n_local) followed by
  ghosts, so kernels see dense index ranges (identical to the paper's
  layout enabling AVX on local tensors; here it enables one BSR over the
  concatenated buffer).
* **Asynchronous halo exchange** (MPI_Isend/Irecv): ``ppermute`` rounds over
  ring shifts. XLA's latency-hiding scheduler overlaps the collective DMA
  with independent compute, which is the paper's parallel-pack /
  non-blocking-issue / wait-free-unpack protocol expressed declaratively.
* **BSP step**: one jitted shard_map program per training step; the jit
  boundary is the barrier.

Everything here is SPMD-uniform: per-rank structures are padded to fleet
maxima and stacked on a leading rank axis, which is what makes the same
program runnable on 8 CPU host-devices in tests and 512 TPU chips in the
dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import axis_size as compat_axis_size

from repro.core.partitioner import PartitionResult, build_local_views
from repro.graph.csr import CSRGraph, csr_from_edges, csr_to_bsr
from repro.kernels import ops as kops


def _ceil_to(x: int, m: int) -> int:
    return max(-(-x // m) * m, m)


@dataclasses.dataclass
class DistributedGraph:
    """Host-built SPMD plan: stacked per-rank BSR + halo schedules."""

    n_ranks: int
    n_local: int  # padded, uniform across ranks, multiple of 128
    n_ghost: int  # padded, uniform, multiple of 128
    max_send: int
    # stacked fwd BSR of local graphs: rows=[local], cols=[local|ghost]
    fwd: dict  # rows/cols/first [P, B], blocks [P, B, br, bc]
    bwd: dict  # BSR of transpose: rows=[local|ghost], cols=[local]
    send_idx: np.ndarray  # [P, P-1, max_send] local idx to send at shift s (-1 pad)
    recv_slot: np.ndarray  # [P, P-1, max_send] ghost slot (0-based in ghost region)
    features: np.ndarray  # [P, n_local, F]
    labels: np.ndarray  # [P, n_local]
    mask: np.ndarray  # [P, n_local] bool (False on padding)
    br: int
    bc: int


def build_distributed_graph(
    graph: CSRGraph,
    features: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
    partition: PartitionResult,
    br: int = 8,
    bc: int = 128,
) -> DistributedGraph:
    P = partition.k
    views = build_local_views(graph, partition.assignment, P)
    n_local = _ceil_to(max(v.n_local for v in views), bc)
    n_ghost = _ceil_to(max(max(v.n_ghost for v in views), 1), bc)

    f_dim = features.shape[1]
    feats = np.zeros((P, n_local, f_dim), dtype=np.float32)
    labs = np.zeros((P, n_local), dtype=np.int32)
    mask = np.zeros((P, n_local), dtype=bool)

    # -- halo schedule: for ring shift s, rank r sends to (r+s)%P ----------
    # pair_nodes[(o, r)] = ordered list of global ids owner o sends to r
    pair_nodes: dict[tuple[int, int], list[int]] = {}
    for v in views:
        for slot, (gid, owner) in enumerate(
            zip(v.global_ids[v.n_local:], v.ghost_owner)
        ):
            pair_nodes.setdefault((int(owner), v.rank), []).append(int(gid))
    max_send = max((len(v) for v in pair_nodes.values()), default=1)
    send_idx = np.full((P, P - 1, max_send), -1, dtype=np.int32)
    recv_slot = np.full((P, P - 1, max_send), -1, dtype=np.int32)

    g2l_local = []  # global -> local index among owned nodes, per rank
    for v in views:
        g2l_local.append({int(g): i for i, g in enumerate(v.global_ids[: v.n_local])})
    ghost_slot_of = []  # global -> slot within ghost region, per rank
    for v in views:
        ghost_slot_of.append(
            {int(g): i for i, g in enumerate(v.global_ids[v.n_local:])}
        )

    for (o, r), nodes in pair_nodes.items():
        s = (r - o) % P
        assert s != 0
        for j, gid in enumerate(nodes):
            send_idx[o, s - 1, j] = g2l_local[o][gid]
            recv_slot[r, s - 1, j] = ghost_slot_of[r][gid]

    # -- per-rank local BSR (padded coords) --------------------------------
    fwd_stack, bwd_stack = [], []
    for v in views:
        # remap ghost columns from (v.n_local + j) to (n_local + j)
        src, dst = v.local_graph.edge_list()
        src = src.astype(np.int64)
        ghost_sel = src >= v.n_local
        src[ghost_sel] = src[ghost_sel] - v.n_local + n_local
        lg = csr_from_edges(
            src=src, dst=dst, n_rows=n_local, n_cols=n_local + n_ghost,
            data=v.local_graph.data, dedupe=False,
        )
        fwd_stack.append(csr_to_bsr(lg, br=br, bc=bc))
        bwd_stack.append(csr_to_bsr(lg.transpose(), br=br, bc=bc))
        feats[v.rank, : v.n_local] = features[v.global_ids[: v.n_local]]
        labs[v.rank, : v.n_local] = labels[v.global_ids[: v.n_local]]
        mask[v.rank, : v.n_local] = train_mask[v.global_ids[: v.n_local]]

    def stack(bsrs):
        n_blocks = max(b.n_blocks for b in bsrs)
        rows = np.zeros((P, n_blocks), dtype=np.int32)
        cols = np.zeros((P, n_blocks), dtype=np.int32)
        first = np.zeros((P, n_blocks), dtype=np.int32)
        blocks = np.zeros((P, n_blocks, br, bc), dtype=np.float32)
        for p, b in enumerate(bsrs):
            k = b.n_blocks
            rows[p, :k] = b.block_rows
            cols[p, :k] = b.block_cols
            first[p, :k] = b.first_in_row
            blocks[p, :k] = b.blocks
            if k < n_blocks:  # zero-block padding accumulates 0 into last row
                rows[p, k:] = b.block_rows[-1]
                cols[p, k:] = 0
        return {"rows": rows, "cols": cols, "first": first, "blocks": blocks}

    return DistributedGraph(
        n_ranks=P, n_local=n_local, n_ghost=n_ghost, max_send=max_send,
        fwd=stack(fwd_stack), bwd=stack(bwd_stack),
        send_idx=send_idx, recv_slot=recv_slot,
        features=feats, labels=labs, mask=mask, br=br, bc=bc,
    )


# ---------------------------------------------------------------------------
# In-step primitives (run inside shard_map, per-rank views)
# ---------------------------------------------------------------------------

def halo_exchange(
    x_local: jax.Array,  # [n_local, F]
    send_idx: jax.Array,  # [P-1, max_send]
    recv_slot: jax.Array,  # [P-1, max_send]
    n_ghost: int,
    axis_name: str,
) -> jax.Array:
    """Ghost-feature exchange: returns [n_ghost, F].

    Each ring shift is: pack (gather) -> ppermute -> unpack (scatter). The
    packs of shift s+1 are independent of the unpacks of shift s, so XLA
    overlaps communication with the next round's packing — the paper's
    split-phase protocol. Autodiff gives the reverse exchange (scatter-add
    of ghost gradients back to owners) for free.
    """
    P = compat_axis_size(axis_name)
    f = x_local.shape[-1]
    ghost = jnp.zeros((n_ghost, f), dtype=x_local.dtype)
    for s in range(1, P):
        idx = send_idx[s - 1]
        valid_send = (idx >= 0)[:, None]
        payload = jnp.where(valid_send, x_local[jnp.clip(idx, 0), :], 0)
        perm = [(r, (r + s) % P) for r in range(P)]
        received = jax.lax.ppermute(payload, axis_name, perm)
        slot = recv_slot[s - 1]
        valid_recv = (slot >= 0)[:, None]
        ghost = ghost.at[jnp.clip(slot, 0)].add(
            jnp.where(valid_recv, received, 0)
        )
    return ghost


def local_fused_aggregate(
    fwd_arrays: tuple,
    bwd_arrays: tuple,
    buf: jax.Array,  # [n_local + n_ghost, F] local|ghost features
    n_local: int,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused local aggregation over the contiguous [local|ghost] buffer."""
    interpret = kops.default_interpret() if interpret is None else interpret
    f = buf.shape[-1]
    bf = min(128, f) if f % 128 != 0 else 128
    f_pad = -(-f // bf) * bf
    buf_p = jnp.pad(buf.astype(jnp.float32), ((0, 0), (0, f_pad - f)))
    y = kops.bsr_spmm_pair(fwd_arrays, bwd_arrays, buf_p, n_local, bf, interpret)
    return y[:, :f].astype(buf.dtype)
